"""Unit tests for the replica-/load-aware routing layer
(``repro.core.routing``) plus the owned-row edge-ship wire format.

Covers ``plan_route`` in isolation (membership = holder union,
rendezvous pinning of fully-replicated queries, stripe ranks,
route-local decimation and its capacity-tier math), the routing-aware
``plan_step_comm`` specs, the engine-level knobs (``route_key``,
``_start_capacity``, ``ExecStats.sites_touched``), and the PR-8 wire
format fix: shipped edge rows are the *distinct resident* rows --
compacted owned rows -- never the padded ``prop_window`` width and
never a replicated duplicate.
"""
import jax
import numpy as np
import pytest

from repro.core.graph import RDFGraph
from repro.core.matching import match_pattern
from repro.core.query import PROP_VAR, QueryGraph
from repro.core.routing import (RoutePlan, plan_route,
                                route_prop_complete)
from repro.core.spmd import (EDGE_ROW_BYTES, SiteStore, SpmdEngine,
                             bind_row_bytes, plan_step_comm)

MULTI = len(jax.devices()) > 1
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="routing outcomes need a multi-device mesh")
# the engine-level expectations below are written against the 4-site
# residency layout; on a 1/2-device mesh the logical sites fold and
# replicated copies collapse into shard-completeness
mesh4 = pytest.mark.skipif(
    len(jax.devices()) != 4,
    reason="residency expectations assume a 4-device mesh")


def _graph(triples, num_v, num_p) -> RDFGraph:
    t = np.asarray(sorted(set(map(tuple, triples))), dtype=np.int64)
    return RDFGraph(t[:, 0], t[:, 1], t[:, 2], num_v, num_p)


@pytest.fixture(scope="module")
def layout():
    """Four properties with known residency over a 4-site split:

    * prop 0 -- split between sites 0 and 1 (incomplete, no overlap);
    * prop 1 -- full copy on BOTH sites 0 and 1, absent elsewhere
      (mesh-incomplete but complete on the {0, 1} route);
    * prop 2 -- replicated on every site (mesh-complete);
    * prop 3 -- split between sites 2 and 3.
    """
    triples = [(i, 0, 200 + i) for i in range(40)]
    triples += [(i, 1, 300 + i) for i in range(12)]
    triples += [(i, 2, 340 + i) for i in range(20)]
    triples += [(i, 3, 380 + i) for i in range(16)]
    g = _graph(triples, 500, 4)
    p = np.asarray(g.p)
    ids = {prop: np.nonzero(p == prop)[0] for prop in range(4)}
    sites = [
        np.unique(np.concatenate([ids[0][0::2], ids[1], ids[2]])),
        np.unique(np.concatenate([ids[0][1::2], ids[1], ids[2]])),
        np.unique(np.concatenate([ids[3][0::2], ids[2]])),
        np.unique(np.concatenate([ids[3][1::2], ids[2]])),
    ]
    return g, SiteStore.build(g, sites), sites


# ----------------------------------------------------------------------
# plan_route: membership, rendezvous, ranks, decimation
# ----------------------------------------------------------------------

def test_route_members_are_incomplete_holder_union(layout):
    g, store, _ = layout
    # mesh-complete prop 2 contributes no members: the route is pinned
    # by the incomplete prop 0, resident on sites 0 and 1 only
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 2)])
    route = plan_route(store, q)
    assert route.members == (0, 1)
    assert route.width == 2 and route.mesh_width == 4
    assert not route.whole_mesh and not route.rendezvous
    # props from disjoint halves of the mesh union to the whole mesh
    q2 = QueryGraph.make([(-1, -2, 0), (-2, -3, 3)])
    route2 = plan_route(store, q2)
    assert route2.members == (0, 1, 2, 3)
    assert route2.whole_mesh


def test_rendezvous_pins_fully_replicated_query(layout):
    g, store, _ = layout
    q = QueryGraph.make([(-1, -2, 2), (-2, -3, 2)])
    route = plan_route(store, q)
    assert route.rendezvous and route.width == 1
    # deterministic: same pattern, same pick, every call
    assert plan_route(store, q).members == route.members
    # the pick is a real mesh device and the only rank >= 0
    (pick,) = route.members
    assert 0 <= pick < 4
    assert [r >= 0 for r in route.seed_ranks] == \
        [j == pick for j in range(4)]


def test_seed_ranks_permute_members_and_mask_outsiders(layout):
    g, store, _ = layout
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 1)])
    route = plan_route(store, q)
    assert route.members == (0, 1)
    member_ranks = sorted(route.seed_ranks[j] for j in route.members)
    assert member_ranks == list(range(route.width))
    for j in range(4):
        assert (route.seed_ranks[j] == -1) == (j not in route.member_set)


def test_route_local_decimation_and_seed_rows(layout):
    g, store, _ = layout
    # seed on prop 1: a full, duplicate-free copy on both route members
    # but NOT mesh-complete -> decimate on the route, tier math applies
    q = QueryGraph.make([(-1, -2, 1), (-1, -3, 0)])
    route = plan_route(store, q)
    assert route.members == (0, 1)
    assert route.decimate and not route.p0_mesh_complete
    assert route.seed_rows == -(-12 // 2)
    # seed on the split prop 0: members hold different halves -> no
    # route-complete seed table, no decimation
    q2 = QueryGraph.make([(-1, -2, 0), (-1, -3, 1)])
    assert not plan_route(store, q2).decimate


def test_route_prop_complete_is_member_local(layout):
    g, store, _ = layout
    assert route_prop_complete(store, 1, (0, 1))
    assert not route_prop_complete(store, 1, (0, 1, 2))
    assert not route_prop_complete(store, 0, (0, 1))
    assert route_prop_complete(store, 2, (0, 1, 2, 3))
    # out-of-metadata props are trivially complete
    assert route_prop_complete(store, 17, (0, 1))


def test_plan_route_falls_back_to_whole_mesh(layout):
    g, store, _ = layout
    # wildcard property: residency is unknowable at plan time
    q = QueryGraph.make([(-1, -2, PROP_VAR)])
    route = plan_route(store, q)
    assert route.whole_mesh and not route.decimate
    # no metadata at all (planner off stores none)
    bare = SiteStore.build(g, [np.arange(g.num_edges)])
    r2 = plan_route(bare, QueryGraph.make([(-1, -2, 0)]))
    assert r2.mesh_width == 1 and r2.whole_mesh


# ----------------------------------------------------------------------
# Routing-aware step specs
# ----------------------------------------------------------------------

def test_route_complete_step_becomes_skip(layout):
    g, store, _ = layout
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 1)])
    route = plan_route(store, q)
    spec = plan_step_comm(store, q, enabled=True, route=route)
    (sc,) = spec
    assert sc.prop == 1
    # mesh-incomplete, but complete on every route member: ship nothing
    assert sc.mode == "skip" and sc.route_complete
    # without the route the same step must ship (prop 1 is not
    # mesh-complete)
    (sc2,) = plan_step_comm(store, q, enabled=True, route=None)
    assert sc2.mode == "dynamic" and not sc2.route_complete


# ----------------------------------------------------------------------
# Engine integration: capacity tier, route_key, sites_touched
# ----------------------------------------------------------------------

@mesh4
def test_start_capacity_lowered_only_for_narrow_decimated_routes(layout):
    g, _, sites = layout
    eng = SpmdEngine(g, sites, capacity=4096)
    # width-2 decimated route, p0 not mesh-complete: one tier down
    q = QueryGraph.make([(-1, -2, 1), (-1, -3, 0)]).normalize()
    assert eng._start_capacity(q) == 2048
    # whole-mesh route: configured capacity untouched
    q2 = QueryGraph.make([(-1, -2, 0), (-2, -3, 3)]).normalize()
    assert eng._start_capacity(q2) == 4096
    # routing off: always the configured capacity
    off = SpmdEngine(g, sites, capacity=4096, routing=False)
    assert off._start_capacity(q) == 4096


@mesh4
def test_route_key_is_stable_and_none_when_inactive(layout):
    g, _, sites = layout
    eng = SpmdEngine(g, sites, capacity=4096)
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 2)])
    key = eng.route_key(q)
    assert key == (0, 1)
    assert eng.route_key(q) == key              # cached + deterministic
    # wildcard-property queries never get a route token
    assert eng.route_key(QueryGraph.make([(-1, -2, PROP_VAR)])) is None
    # routing off: no token, buckets fall back to pure shape keys
    off = SpmdEngine(g, sites, capacity=4096, routing=False)
    assert off.route_key(q) is None


@mesh4
def test_sites_touched_shrinks_to_route_members(layout):
    g, _, sites = layout
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 1)])
    want = match_pattern(g, q).num_rows
    eng = SpmdEngine(g, sites, capacity=4096)
    r = eng.execute(q)
    assert r.num_rows == want
    assert r.stats.sites_touched == {0, 1}
    off = SpmdEngine(g, sites, capacity=4096, routing=False)
    r2 = off.execute(q)
    assert r2.num_rows == want
    assert r2.stats.sites_touched == {0, 1, 2, 3}


# ----------------------------------------------------------------------
# Owned-row edge-ship wire format (PR-8 fix regression)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def overlap_setup():
    """Dense seed prop 0 split across all sites; tiny prop 1 stored as
    a FULL copy on sites 0 and 1 (12 distinct edges, 24 stored rows):
    the edge-ship step must put 12 rows on the wire, not 24 and not a
    padded window."""
    rng = np.random.default_rng(3)
    triples = [(int(s), 0, int(o))
               for s, o in zip(rng.integers(0, 40, 3000),
                               rng.integers(40, 80, 3000))]
    triples += [(40 + i, 1, 100 + i) for i in range(12)]
    g = _graph(triples, 200, 2)
    p = np.asarray(g.p)
    dense = np.nonzero(p == 0)[0]
    small = np.nonzero(p == 1)[0]
    sites = [np.unique(np.concatenate([dense[0::4], small])),
             np.unique(np.concatenate([dense[1::4], small])),
             dense[2::4], dense[3::4]]
    return g, sites


def test_edge_ship_rows_are_distinct_resident_rows(overlap_setup):
    g, sites = overlap_setup
    store = SiteStore.build(g, sites)
    q = QueryGraph.make([(-1, -2, 0), (-2, -3, 1)])
    (sc,) = plan_step_comm(store, q, enabled=True)
    assert sc.mode == "dynamic"
    # 12 distinct resident edges, even though 24 rows are stored and
    # the per-device gather buffer pads to a multiple of 8
    assert sc.edge_rows == 12
    assert sc.edge_bytes == 12 * EDGE_ROW_BYTES
    assert sc.gather_cap >= 12
    # ownership is exclusive: the 12 shipped rows come from exactly one
    # holder each (here the lowest site holding the copy)
    assert int(store.prop_dev_owned[:, 1].sum()) == 12


@mesh4
def test_edge_ship_ledger_pinned_to_valid_row_count(overlap_setup):
    """End to end: the ledgered (and traced) bytes of the edge-ship
    step are ``(w - 1) * distinct_rows * EDGE_ROW_BYTES`` -- a
    replicated copy is not shipped twice, padding is not shipped at
    all, and the answer stays exact."""
    g, sites = overlap_setup
    q = QueryGraph.make([(-1, -2, 0), (-2, -3, 1)])
    want = match_pattern(g, q).num_rows
    eng = SpmdEngine(g, sites, capacity=1 << 15)
    assert eng.execute(q).num_rows == want
    extra = eng.stats().extra
    assert extra["capacity_retries"] == 0
    assert extra["edge_shipped_steps"] == 1
    m = len(jax.devices())
    # whole-mesh route here (prop 0 lives everywhere), so w == m
    expect = (m - 1) * (12 * EDGE_ROW_BYTES + want * bind_row_bytes(3))
    assert eng.stats().comm_bytes == expect
