"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, output shapes + no NaNs (brief req)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, input_specs
from repro.models import get_api, init_params, param_count
from repro.optim import AdamWConfig, adamw_init, adamw_update

ALL_ARCH_IDS = sorted(ARCHS)


@pytest.mark.parametrize("arch_id", ALL_ARCH_IDS)
def test_smoke_forward(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    api = get_api(cfg)
    params = init_params(api.defs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 32
    if cfg.embed_inputs:
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                              jnp.float32)
    else:
        x = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                               cfg.vocab_size)
    logits, aux = jax.jit(lambda p, t: api.apply(cfg, p, t))(params, x)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch_id", ALL_ARCH_IDS)
@pytest.mark.slow
def test_smoke_train_step(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    api = get_api(cfg)
    params = init_params(api.defs(cfg), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    B, S = 2, 16
    if cfg.embed_inputs:
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                              jnp.float32)
    else:
        x = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                               cfg.vocab_size)
    y = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    @jax.jit
    def step(p, o, xx, yy):
        loss, grads = jax.value_and_grad(
            lambda pp: api.loss(cfg, pp, xx, yy))(p)
        p2, o2, gn = adamw_update(p, grads, o, opt_cfg)
        return p2, o2, loss, gn

    p2, o2, loss, gn = step(params, opt, x, y)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(gn))
    # parameters actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch_id", ALL_ARCH_IDS)
def test_smoke_decode_step(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    api = get_api(cfg)
    params = init_params(api.defs(cfg), jax.random.PRNGKey(0))
    B = 2
    cache = api.init_cache(cfg, B, 32)
    tok = (jax.random.normal(jax.random.PRNGKey(3), (B, cfg.d_model),
                             jnp.float32) if cfg.embed_inputs
           else jnp.zeros((B,), jnp.int32))
    logits, cache2 = jax.jit(
        lambda p, t, c: api.decode(cfg, p, t, c, jnp.int32(0)))(
        params, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch_id", ALL_ARCH_IDS)
def test_input_specs_all_shapes(arch_id):
    """input_specs produces well-formed ShapeDtypeStructs per live cell."""
    spec = get_arch(arch_id)
    for sname, sh in spec.shapes.items():
        if sh.skip:
            assert sh.skip_reason
            continue
        ins = input_specs(spec, sname)
        leaves = jax.tree.leaves(ins)
        assert leaves and all(isinstance(l, jax.ShapeDtypeStruct)
                              for l in leaves)


@pytest.mark.parametrize("arch_id", ["qwen3-1.7b", "mixtral-8x7b",
                                     "rwkv6-1.6b"])
@pytest.mark.slow
def test_decode_matches_full_forward(arch_id):
    """Step-by-step decode logits == full-sequence forward logits."""
    cfg = get_arch(arch_id).smoke
    api = get_api(cfg)
    params = init_params(api.defs(cfg), jax.random.PRNGKey(0))
    T = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0,
                              cfg.vocab_size)
    full, _ = api.apply(cfg, params, toks)
    cache = api.init_cache(cfg, 1, T)
    outs = []
    for t in range(T):
        lg, cache = api.decode(cfg, params, toks[:, t], cache, jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(dec.astype(jnp.float32)
                        - full.astype(jnp.float32)).max())
    assert err < 0.25, f"decode/forward divergence {err}"  # bf16 tolerance


def test_exact_published_configs():
    """The full configs carry the exact assigned hyperparameters."""
    c = get_arch("mixtral-8x7b").config
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.top_k) == \
        (32, 4096, 32, 8, 14336, 32000, 8, 2)
    c = get_arch("llama3-405b").config
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = get_arch("jamba-1.5-large-398b").config
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.num_experts, c.top_k, c.attn_every) == (72, 8192, 64, 8, 16, 2, 8)
    c = get_arch("qwen2-moe-a2.7b").config
    assert (c.num_experts, c.top_k, c.num_shared_experts, c.moe_d_ff) == \
        (60, 4, 4, 1408)
    c = get_arch("rwkv6-1.6b").config
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (24, 2048, 7168, 65536)
    c = get_arch("nemotron-4-15b").config
    assert c.mlp_act == "sq_relu" and c.vocab_size == 256000
    c = get_arch("qwen2.5-3b").config
    assert c.qkv_bias and c.num_kv_heads == 2 and c.d_ff == 11008
    c = get_arch("qwen3-1.7b").config
    assert c.qk_norm and c.d_ff == 6144
    c = get_arch("musicgen-medium").config
    assert c.embed_inputs and c.vocab_size == 2048 and c.d_model == 1536
    c = get_arch("pixtral-12b").config
    assert c.embed_inputs and c.d_model == 5120 and c.num_layers == 40
