"""Plan lifecycle end-to-end: serving through a re-partition on the
SPMD backend, versioned plan publication, and graph-delta ingestion.

The flagship harness drives a drifting query stream through an
``AdaptiveEngine`` whose data plane is the jit/shard_map ``SpmdEngine``
until drift fires a re-partition, and asserts

* answer-set equality against whole-graph ``match_pattern`` for every
  query -- before, during (the query whose epoch boundary triggers the
  swap), and after the hot ``SiteStore`` swap, at whatever device
  count the suite runs (CI: 1, 2 and 4);
* the SPMD trace <-> comm-ledger delta stays exactly 0 across the
  swap: the per-step records of every traced query sum to its ledger
  bytes on both the old and the new store generation.

The serving cut-over test drives a manual-pump ``FrontDoor`` through
``request_swap`` and checks in-flight batches finish while every batch
dispatched after the swap runs on the new store.
"""
import numpy as np
import pytest

from generators import answer_set
from repro.core import (PartitionConfig, build_plan,
                        generate_drifting_workload, generate_watdiv)
from repro.core.matching import match_pattern
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.online import (AdaptiveConfig, AdaptiveEngine, PlanRepository,
                          WorkloadMonitor, ingest_delta)
from repro.serve import FrontDoor, FrontDoorConfig


@pytest.fixture(scope="module")
def lifecycle_setup():
    g = generate_watdiv(3_000, seed=3)
    wl = generate_drifting_workload(g, [(300, {})], seed=11)
    plan = build_plan(g, wl, PartitionConfig(kind="vertical", num_sites=4))
    return g, wl, plan


def _drifting_stream(g, seed=23):
    return generate_drifting_workload(
        g, [(100, {}), (300, {"S": 12.0})], seed=seed).queries


# ----------------------------------------------------------------------
# Adaptive over SPMD: parity through the hot swap
# ----------------------------------------------------------------------

@pytest.mark.slow
def test_adaptive_spmd_parity_through_repartition(lifecycle_setup):
    g, wl, plan = lifecycle_setup
    tracer = Tracer(enabled=True, capacity=8)
    eng = AdaptiveEngine(plan, AdaptiveConfig(
        epoch_len=100, serve_backend="spmd",
        migration_budget_bytes=2_000_000))
    eng.set_tracer(tracer)
    eng.set_metrics_registry(MetricsRegistry())
    spmd = eng.engine
    gen_seen = {0}
    for q in _drifting_stream(g):
        before_comm = spmd.stats().comm_bytes
        r = eng.execute(q)
        # exactness vs the whole-graph oracle at every store generation
        assert answer_set(r) == answer_set(match_pattern(g, q))
        # trace <-> ledger delta stays 0 across the swap: the traced
        # step bytes of this query sum exactly to its ledger delta
        delta = spmd.stats().comm_bytes - before_comm
        root = tracer.store.spans()[-1]
        assert root.attrs["backend"] == "adaptive"
        recs = [rec for s in root.walk() for rec in s.records
                if rec["kind"] == "comm_step"]
        assert sum(rec["bytes"] for rec in recs) == delta
        gen_seen.add(spmd.store_generation)
    assert eng.num_repartitions >= 1
    # the data plane survived the re-partition: same engine object,
    # bumped store generation, swap counted in the stats
    assert eng.engine is spmd
    assert spmd.store_generation >= 1 and len(gen_seen) >= 2
    assert spmd.stats().extra["store_swaps"] == spmd.store_generation
    # the refreshed plan artifact matches the live engine state
    assert eng.plan.frag is eng.frag
    assert set(eng.plan.replicated_props) == eng.replicated_props


@pytest.mark.slow
def test_frontdoor_serves_across_requested_swap(lifecycle_setup):
    """Manual-pump cut-over: batches pumped before the swap run on the
    old store, the swap applies between dispatches, batches pumped
    after run on the new store -- every answer exact throughout."""
    g, wl, plan = lifecycle_setup
    spmd = plan.build_spmd_engine()
    door = FrontDoor(spmd, FrontDoorConfig(max_queue=64, max_batch=4),
                     start=False, registry=MetricsRegistry())
    queries = wl.queries[:8]
    futs = [door.submit(q) for q in queries[:4]]
    door.drain()

    sids = plan.site_edge_ids()
    door.request_swap(lambda: spmd.swap_store(
        sids[1:] + sids[:1], replicated_props=set(plan.replicated_props)))
    # the swap is queued, not applied: dispatch context only
    assert spmd.store_generation == 0 and door.swaps_applied == 0
    futs += [door.submit(q) for q in queries[4:]]
    door.drain()
    assert door.swaps_applied == 1 and spmd.store_generation == 1
    for q, f in zip(queries, futs):
        assert f.outcome == "completed"
        assert answer_set(f.result()) == answer_set(match_pattern(g, q))
    assert door.stats()["failed"] == 0


# ----------------------------------------------------------------------
# Plan repository
# ----------------------------------------------------------------------

def test_plan_repository_publish_load_provenance(lifecycle_setup, tmp_path):
    g, wl, plan = lifecycle_setup
    repo = PlanRepository(tmp_path / "repo")
    assert repo.latest() is None
    with pytest.raises(FileNotFoundError):
        repo.load_latest(g)

    mon = WorkloadMonitor(g.num_properties)
    mon.bulk_load(wl)
    v1 = repo.publish(plan, monitor=mon, reason="initial build")
    assert v1 == 1 and repo.versions() == [1]
    prov = repo.provenance(v1)
    assert prov["parent"] is None and prov["reason"] == "initial build"
    assert prov["strategy"] == plan.strategy

    loaded = repo.load_version(v1, g)
    assert len(loaded.frag.fragments) == len(plan.frag.fragments)
    assert ([p.canonical_code() for p in loaded.selected_patterns]
            == [p.canonical_code() for p in plan.selected_patterns])
    # a wrong graph is rejected by the plan loader's signature check
    with pytest.raises(ValueError, match="different graph"):
        repo.load_version(v1, generate_watdiv(1_000, seed=9))

    # monitor state resumes with identical statistics
    mon2 = repo.load_monitor(v1)
    assert np.allclose(mon.property_distribution(),
                       mon2.property_distribution())
    u1, w1 = mon.snapshot()
    u2, w2 = mon2.snapshot()
    assert np.array_equal(w1, w2)

    # warm-started rebuild publishes as a provenance-chained child
    warm = build_plan(g, wl, plan.config, incumbent=repo.load_latest(g))
    v2 = repo.publish(warm, reason="warm rebuild")
    assert v2 == 2 and repo.provenance(v2)["parent"] == v1
    assert repo.latest() == 2
    # the warm start retained incumbent patterns (integrity seeds stay
    # hot under the same workload)
    inc = {p.canonical_code() for p in plan.selected_patterns}
    new = {p.canonical_code() for p in warm.selected_patterns}
    assert inc & new


def test_plan_repository_monitor_optional(lifecycle_setup, tmp_path):
    g, wl, plan = lifecycle_setup
    repo = PlanRepository(tmp_path / "repo")
    v = repo.publish(plan)
    with pytest.raises(FileNotFoundError, match="monitor"):
        repo.load_monitor(v)


# ----------------------------------------------------------------------
# Graph-delta ingestion
# ----------------------------------------------------------------------

def _delta(g, n_add=50, n_remove=30, seed=7):
    rng = np.random.default_rng(seed)
    add = np.stack([rng.integers(0, g.num_vertices, n_add),
                    rng.integers(0, g.num_properties, n_add),
                    rng.integers(0, g.num_vertices, n_add)], axis=1)
    rem_idx = rng.choice(g.num_edges, n_remove, replace=False)
    rem = np.stack([g.s[rem_idx], g.p[rem_idx], g.o[rem_idx]], axis=1)
    return add, rem


def test_apply_delta_set_semantics(lifecycle_setup):
    g, _, _ = lifecycle_setup
    add, rem = _delta(g)
    g2 = g.apply_delta(added_edges=add, removed_edges=rem)
    # removals by value, additions deduped: |E'| = |E| - removed + fresh
    assert g2.num_edges < g.num_edges + len(add)
    assert g2.num_edges > g.num_edges - len(rem)
    # re-adding resident triples is a no-op (RDF set semantics)
    g3 = g2.apply_delta(added_edges=add)
    assert g3.num_edges == g2.num_edges
    # removing then re-adding round-trips the edge count
    tri = (int(g2.s[0]), int(g2.p[0]), int(g2.o[0]))
    g4 = g2.apply_delta(removed_edges=[tri]).apply_delta(added_edges=[tri])
    assert g4.num_edges == g2.num_edges
    # the property universe is fixed plan state
    with pytest.raises(ValueError, match="property"):
        g.apply_delta(added_edges=[(0, g.num_properties, 0)])


def test_ingest_delta_ships_diffs_not_fragments(lifecycle_setup):
    g, wl, plan = lifecycle_setup
    add, rem = _delta(g)
    g2 = g.apply_delta(added_edges=add, removed_edges=rem)
    dp = ingest_delta(plan, g2, budget_bytes=10**6)
    assert dp.added_edges > 0 and dp.removed_edges > 0
    assert dp.unassigned == 0
    # the point of the exercise: edge diffs, never whole fragments
    assert dp.shipped_bytes < dp.whole_bytes
    assert dp.migration.moved_bytes == dp.shipped_bytes
    assert all(mv.mandatory for mv in dp.migration.applied)
    assert dp.makespan_sec > 0.0
    # the rebuilt plan covers the new graph at the same placement
    assert dp.plan.graph is g2
    assert dp.plan.frag.coverage_ok(g2)
    assert np.array_equal(dp.plan.alloc.site_of, plan.alloc.site_of)
    # every delta names a real diff on a fragment's owning site
    for d in dp.deltas:
        assert d.added.size + d.removed > 0
        assert 0 <= d.site < plan.config.num_sites


@pytest.mark.slow
def test_ingest_delta_served_through_hot_swap(lifecycle_setup):
    """The delta-ingestion serve path: swap the rebuilt plan's storage
    (and the new graph) into a running SPMD engine, answers exact on
    the new graph for queries probing both surviving and added
    edges."""
    g, wl, plan = lifecycle_setup
    add, rem = _delta(g)
    g2 = g.apply_delta(added_edges=add, removed_edges=rem)
    dp = ingest_delta(plan, g2, budget_bytes=10**6)
    eng = plan.build_spmd_engine()
    probes = wl.queries[:6]
    pre = [answer_set(eng.execute(q)) for q in probes]
    assert pre == [answer_set(match_pattern(g, q)) for q in probes]
    eng.swap_store(dp.plan.site_edge_ids(),
                   replicated_props=set(dp.plan.replicated_props),
                   graph=g2)
    for q in probes:
        assert answer_set(eng.execute(q)) == answer_set(
            match_pattern(g2, q))


def test_ingest_delta_empty_delta_is_noop(lifecycle_setup):
    g, _, plan = lifecycle_setup
    dp = ingest_delta(plan, g.apply_delta())
    assert dp.added_edges == 0 and dp.removed_edges == 0
    assert dp.shipped_bytes == 0 and not dp.deltas
    assert dp.plan.frag.coverage_ok(dp.plan.graph)
