"""Unit tests for the SPMD size-aware communication planner.

Covers the decision logic in isolation (``SiteStore`` residency
metadata, ``plan_step_comm`` static specs) and end-to-end: skewed
binding/edge sizes must ship the smaller side, shard-complete
properties must produce zero gathers, the ``stats()`` counters must
record every per-step outcome, and the planned ledger must never
exceed the naive gather-every-step ledger on the star/chain/cycle
workload (tests/conftest.py forces a 4-device host mesh by default;
decision-outcome tests are skipped on a 1-device mesh, where no
inter-device step exists at all).
"""
import jax
import numpy as np
import pytest

from repro.core import Session, make_shape_queries
from repro.core.graph import RDFGraph
from repro.core.matching import match_pattern
from repro.core.query import QueryGraph
from repro.core.spmd import (COMM_EDGE, COMM_GATHER, COMM_SKIP, SiteStore,
                             SpmdEngine, plan_step_comm)

MULTI = len(jax.devices()) > 1
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="decision outcomes need a multi-device mesh")


def _graph(triples, num_v, num_p) -> RDFGraph:
    t = np.asarray(sorted(set(map(tuple, triples))), dtype=np.int64)
    return RDFGraph(t[:, 0], t[:, 1], t[:, 2], num_v, num_p)


def _round_robin_sites(g: RDFGraph, m: int = 4):
    return [np.arange(g.num_edges)[i::m] for i in range(m)]


@pytest.fixture(scope="module")
def skew_graph() -> RDFGraph:
    """prop 0: dense block (bindings explode); prop 1: a dozen edges
    (tiny table); prop 2: medium."""
    rng = np.random.default_rng(0)
    triples = [(int(s), 0, int(o))
               for s, o in zip(rng.integers(0, 40, 3000),
                               rng.integers(40, 80, 3000))]
    triples += [(40 + i, 1, 100 + i) for i in range(12)]
    triples += [(int(s), 2, int(o))
                for s, o in zip(rng.integers(0, 40, 200),
                                rng.integers(40, 80, 200))]
    return _graph(triples, 120, 3)


# ----------------------------------------------------------------------
# Static metadata + spec (device-count independent)
# ----------------------------------------------------------------------

def test_sitestore_residency_metadata(skew_graph):
    g = skew_graph
    store = SiteStore.build(g, _round_robin_sites(g))
    assert store.prop_dev_rows.shape == (4, g.num_properties)
    # round-robin split: every device holds a strict subset of each
    # dense property, and the per-device rows sum to the resident total
    for prop in range(g.num_properties):
        total, per_dev_max = store.prop_rows(prop)
        assert total == int((np.asarray(g.p) == prop).sum())
        assert per_dev_max == int(store.prop_dev_rows[:, prop].max())
    assert not store.prop_shard_complete(0)
    # out-of-range property: resident nowhere, trivially complete
    assert store.prop_shard_complete(g.num_properties + 3)
    assert store.prop_rows(g.num_properties + 3) == (0, 0)


def test_sitestore_detects_replicated_property_as_complete(skew_graph):
    g = skew_graph
    rep = np.nonzero(np.asarray(g.p) == 1)[0]
    rest = np.nonzero(np.asarray(g.p) != 1)[0]
    sites = [np.unique(np.concatenate([rep, rest[i::4]])) for i in range(4)]
    store = SiteStore.build(g, sites)
    assert store.prop_shard_complete(1)
    assert not store.prop_shard_complete(0)


def test_plan_step_comm_specs(skew_graph):
    g = skew_graph
    rep = np.nonzero(np.asarray(g.p) == 1)[0]
    rest = np.nonzero(np.asarray(g.p) != 1)[0]
    sites = [np.unique(np.concatenate([rep, rest[i::4]])) for i in range(4)]
    store = SiteStore.build(g, sites)
    q = QueryGraph.make([(-1, -2, 0), (-2, -3, 1), (-3, -4, 2)])
    spec = plan_step_comm(store, q, enabled=True)
    assert len(spec) == 2                      # one per join step >= 1
    by_prop = {sc.prop: sc for sc in spec}
    assert by_prop[1].mode == "skip"           # replicated everywhere
    assert by_prop[2].mode == "dynamic"
    assert by_prop[2].edge_rows == int(store.prop_dev_rows[:, 2].sum())
    assert by_prop[2].gather_cap >= int(store.prop_dev_rows[:, 2].max())
    naive = plan_step_comm(store, q, enabled=False)
    assert [sc.mode for sc in naive] == ["gather", "gather"]


# ----------------------------------------------------------------------
# Decision outcomes end-to-end (need a real mesh)
# ----------------------------------------------------------------------

@needs_mesh
def test_smaller_side_edges_win_on_skewed_sizes(skew_graph):
    """Huge binding table, tiny property table: the planner must ship
    the edge rows, answer exactly, and undercut the naive ledger."""
    g = skew_graph
    q = QueryGraph.make([(-1, -2, 0), (-2, -3, 1)])
    want = match_pattern(g, q).num_rows
    ledgers = {}
    for comm_plan in (True, False):
        eng = SpmdEngine(g, _round_robin_sites(g), capacity=4096,
                         comm_plan=comm_plan)
        assert eng.execute(q).num_rows == want
        ledgers[comm_plan] = eng.stats().comm_bytes
        extra = eng.stats().extra
        if comm_plan:
            assert extra["edge_shipped_steps"] >= 1
            assert extra["comm_bytes_saved"] > 0
        else:
            assert extra["gather_steps"] >= 1
            assert extra["edge_shipped_steps"] == 0
    assert ledgers[True] < ledgers[False]


@needs_mesh
def test_smaller_side_bindings_win_on_tiny_binding_table(skew_graph):
    """Rooting the match on the 12-edge property keeps the binding
    table tiny while the join property is dense (3000 edges): the
    planner must keep gathering bindings.  (Constants cannot pin the
    table here -- they are normalized out of the compiled pattern and
    re-applied host-side.)"""
    g = skew_graph
    q = QueryGraph.make([(-1, -2, 1), (-4, -1, 0)])
    want = match_pattern(g, q).num_rows
    assert want > 0
    eng = SpmdEngine(g, _round_robin_sites(g), capacity=4096)
    assert eng.execute(q).num_rows == want
    extra = eng.stats().extra
    assert extra["gather_steps"] >= 1
    assert extra["edge_shipped_steps"] == 0


@needs_mesh
def test_shard_complete_property_skips_every_gather(skew_graph):
    """Every join step on a property replicated across all devices:
    zero gathers, zero edge ships.  Routed (default), such a
    fully-replicated query is rendezvous-pinned to ONE device -- no
    peers, zero comm altogether.  Unrouted (``routing=False``), comm is
    only the final result gather: step 0's property is complete, so
    the seeds are decimated across the mesh and the final gather ships
    the answer exactly once (not one duplicate per device)."""
    g = skew_graph
    rep = np.nonzero(np.asarray(g.p) != 0)[0]      # props 1 and 2 everywhere
    rest = np.nonzero(np.asarray(g.p) == 0)[0]
    sites = [np.unique(np.concatenate([rep, rest[i::4]])) for i in range(4)]
    q = QueryGraph.make([(-1, -2, 2), (-2, -3, 1)])
    want = match_pattern(g, q).num_rows
    routed = SpmdEngine(g, sites, capacity=4096)
    assert routed.execute(q).num_rows == want
    rextra = routed.stats().extra
    assert rextra["routed_queries"] == 1
    assert rextra["skipped_gathers"] == 1
    assert rextra["gather_steps"] == 0
    assert rextra["edge_shipped_steps"] == 0
    assert routed.stats().comm_bytes == 0
    # whole-mesh execution restored: decimation across the full mesh,
    # the final full-width gather at exactly one copy of the answer
    eng = SpmdEngine(g, sites, capacity=4096, routing=False)
    r = eng.execute(q)
    assert r.num_rows == want
    extra = eng.stats().extra
    assert extra["routed_queries"] == 0
    assert extra["skipped_gathers"] == 1
    assert extra["gather_steps"] == 0
    assert extra["edge_shipped_steps"] == 0
    assert extra["decimated_seed_queries"] == 1
    m = len(jax.devices())
    assert eng.stats().comm_bytes == (m - 1) * want * (3 * 4 + 1)
    # planner off = the faithful naive baseline: no decimation, every
    # step gathers, every device computes (and ships) the full answer
    naive = SpmdEngine(g, sites, capacity=4096, comm_plan=False)
    assert naive.execute(q).num_rows == want
    nextra = naive.stats().extra
    assert nextra["decimated_seed_queries"] == 0
    assert nextra["skipped_gathers"] == 0
    assert naive.stats().comm_bytes > eng.stats().comm_bytes


@needs_mesh
def test_planner_decision_vector_matches_counters(skew_graph):
    """The per-step decision vector the matcher returns is what the
    counters aggregate: one decision per join step per attempt."""
    g = skew_graph
    eng = SpmdEngine(g, _round_robin_sites(g), capacity=4096)
    q = QueryGraph.make([(-1, -2, 0), (-2, -3, 1), (-1, -4, 2)])
    eng.execute(q)
    extra = eng.stats().extra
    n_steps = (extra["gather_steps"] + extra["edge_shipped_steps"]
               + extra["skipped_gathers"])
    assert n_steps == 2 * (extra["capacity_retries"] + 1)


# ----------------------------------------------------------------------
# Ledger: planned <= naive on the star/chain/cycle workload
# ----------------------------------------------------------------------

@pytest.mark.slow
def test_planned_ledger_never_exceeds_naive(skew_graph):
    """Planned <= naive on this (seeded, deterministic) star/chain/
    cycle workload.  NOTE this is a workload-level empirical property,
    not a mechanism invariant: skipping the gather also skips the
    cross-device dedup and redistributes expansion load, so pathological
    capacity/skew combinations can retry (and re-ledger) tiers the naive
    plan avoids.  The bench (`bench_spmd_comm`) reports the same
    comparison on the paper-scale workload."""
    g = skew_graph
    rng = np.random.default_rng(42)
    shapes = make_shape_queries(
        lambda: int(rng.integers(0, g.num_properties)))
    per_shape = {}
    for name, q in shapes.items():
        want = match_pattern(g, q).num_rows
        bytes_by_mode = {}
        for comm_plan in (True, False):
            eng = SpmdEngine(g, _round_robin_sites(g), capacity=8192,
                             comm_plan=comm_plan)
            assert eng.execute(q).num_rows == want, (name, comm_plan)
            bytes_by_mode[comm_plan] = eng.stats().comm_bytes
        per_shape[name] = bytes_by_mode
        assert bytes_by_mode[True] <= bytes_by_mode[False], name
    if MULTI:
        assert any(v[True] < v[False] for v in per_shape.values()), per_shape


# ----------------------------------------------------------------------
# Allocation-aware replication: planner, seed decimation, edge cache
# ----------------------------------------------------------------------

def _heat_graph() -> RDFGraph:
    """prop 0: 100 edges, prop 1: 10, prop 2: 50 -- known replica costs
    for the greedy-knapsack assertions."""
    triples = [(i, 0, 200 + i) for i in range(100)]
    triples += [(i, 1, 320 + i) for i in range(10)]
    triples += [(i, 2, 340 + i) for i in range(50)]
    return _graph(triples, 400, 3)


def test_plan_replication_ranks_heat_per_byte():
    from repro.core import plan_replication
    g = _heat_graph()
    sites = 4
    heat = np.array([10.0, 9.0, 1.0])
    # replica cost = rows * 12 * (sites - 1): 3600 / 360 / 1800 bytes;
    # heat per byte ranks prop 1 >> prop 0 > prop 2
    rp = plan_replication(g, sites, 10 ** 9, heat)
    assert rp.props == [1, 0, 2]
    assert rp.cost_bytes == {0: 3600, 1: 360, 2: 1800}
    assert rp.spent_bytes == 5760
    # budget for prop 1 only
    assert plan_replication(g, sites, 360, heat).props == [1]
    # a candidate that does not fit is skipped, not a stopping point:
    # prop 0 (rank 2) busts this budget but prop 2 (rank 3) still fits
    rp = plan_replication(g, sites, 360 + 1800, heat)
    assert rp.props == [1, 2]
    assert rp.within_budget()


def test_plan_replication_zero_budget_and_zero_heat():
    from repro.core import plan_replication
    g = _heat_graph()
    assert plan_replication(g, 4, 0, np.ones(3)).props == []
    # one site: replication is meaningless, nothing is chosen
    assert plan_replication(g, 1, 10 ** 9, np.ones(3)).props == []
    # heat-zero properties are never candidates, whatever the budget
    rp = plan_replication(g, 4, 10 ** 9, np.array([0.0, 5.0, 0.0]))
    assert rp.props == [1]
    assert set(rp.heat) == {1}


def test_replicated_plan_makes_hot_props_shard_complete():
    """End to end through build_plan: the replicated plan's SPMD store
    reports the chosen properties shard-complete and the engine carries
    the provenance counter."""
    from repro.core import PartitionConfig, Workload, build_plan
    g = _heat_graph()
    qs = [QueryGraph.make([(-1, -2, 0), (-1, -3, 1)]),
          QueryGraph.make([(-1, -2, 1)])]
    plan = build_plan(g, Workload(qs), PartitionConfig(
        kind="shape", num_sites=4, replication_budget_bytes=400))
    assert plan.replicated_props == {1}          # hottest per byte
    eng = plan.build_spmd_engine(capacity=1024)
    assert eng.store.prop_shard_complete(1)
    assert eng.replicated_props == {1}
    assert eng.stats().extra["replicated_props"] == 1.0
    # the uniform storage view reaches the baseline backend too: every
    # site of the gather-all engine holds every prop-1 edge
    beng = plan.build_baseline_engine()
    rep_ids = set(np.nonzero(np.asarray(g.p) == 1)[0].tolist())
    for site_edges in beng.frag.site_edges:
        assert rep_ids <= set(np.asarray(site_edges).tolist())


@needs_mesh
def test_seed_decimation_partitions_replicated_seeds(skew_graph):
    """Step 0 on a fully replicated property: without decimation every
    device would duplicate every seed (m-fold final gather).  With it
    the ledger's final gather ships each answer exactly once."""
    g = skew_graph
    rep = np.nonzero(np.asarray(g.p) == 2)[0]       # prop 2 everywhere
    rest = np.nonzero(np.asarray(g.p) != 2)[0]
    sites = [np.unique(np.concatenate([rep, rest[i::4]])) for i in range(4)]
    q = QueryGraph.make([(-1, -2, 2), (-1, -3, 0)])  # seed on replicated 2
    want_vars = match_pattern(g, q)
    eng = SpmdEngine(g, sites, capacity=1 << 15)
    r = eng.execute(q)
    assert r.num_rows == want_vars.num_rows
    extra = eng.stats().extra
    assert extra["decimated_seed_queries"] == 1
    assert extra["capacity_retries"] == 0


@needs_mesh
def test_edge_cache_reuses_gather_across_steps(skew_graph):
    """Two join steps on the same (non-complete) property inside one
    query: the first ships the property's edge rows, the second reuses
    the gathered table from the trace cache -- one ship, one hit,
    exact answers."""
    g = skew_graph
    q = QueryGraph.make([(-1, -2, 0), (-1, -3, 2), (-1, -4, 2)])
    want = match_pattern(g, q).num_rows
    eng = SpmdEngine(g, _round_robin_sites(g), capacity=1 << 16)
    assert eng.execute(q).num_rows == want
    extra = eng.stats().extra
    assert extra["edge_shipped_steps"] == 1
    assert extra["edge_cache_hits"] == 1
    assert extra["capacity_retries"] == 0


def test_single_device_mesh_ships_nothing():
    """On a 1-device mesh every step is local: zero comm, zero step
    counters, regardless of planner mode."""
    if MULTI:
        pytest.skip("needs a 1-device mesh (CI runs the suite there)")
    g = _graph([(i, 0, i + 1) for i in range(20)]
               + [(i + 1, 1, i + 2) for i in range(20)], 40, 2)
    for comm_plan in (True, False):
        eng = SpmdEngine(g, [np.arange(g.num_edges)], comm_plan=comm_plan)
        eng.execute(QueryGraph.make([(-1, -2, 0), (-2, -3, 1)]))
        st = eng.stats()
        assert st.comm_bytes == 0
        assert st.extra["gather_steps"] == 0
        assert st.extra["skipped_gathers"] == 0
        assert st.extra["edge_shipped_steps"] == 0
