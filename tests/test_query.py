"""Query-graph layer: canonical DFS codes, normalization, subgraph iso."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from seeded_fallback import given, settings, st

from repro.core.query import (QueryGraph, all_embeddings, find_embedding,
                              is_subgraph_of, min_dfs_code)


def V(i):
    return -(i + 1)


def test_normalize_replaces_constants():
    q = QueryGraph.make([(5, V(0), 2), (V(0), 9, 3)])
    n = q.normalize()
    assert all(v < 0 for v in n.vertices())
    assert n.properties() == [2, 3]


def test_constant_bindings_align_with_normalize():
    q = QueryGraph.make([(5, V(0), 2), (V(0), 9, 3)])
    binds = q.constant_bindings()
    assert set(binds.values()) == {5, 9}


def test_canonical_code_distinguishes_structure():
    star = QueryGraph.make([(V(0), V(1), 1), (V(0), V(2), 2)])
    path = QueryGraph.make([(V(0), V(1), 1), (V(1), V(2), 2)])
    assert star.canonical_code() != path.canonical_code()


def test_canonical_code_direction_sensitivity():
    a = QueryGraph.make([(V(0), V(1), 1)])
    b = QueryGraph.make([(V(1), V(0), 1)])
    # single edge with variables: same canonical form regardless of naming
    assert a.canonical_code() == b.canonical_code()
    fwd = QueryGraph.make([(V(0), V(1), 1), (V(1), V(2), 1)])
    fan = QueryGraph.make([(V(0), V(1), 1), (V(2), V(1), 1)])
    assert fwd.canonical_code() != fan.canonical_code()


@st.composite
def small_graphs(draw):
    n_edges = draw(st.integers(1, 5))
    n_vars = draw(st.integers(1, 4))
    edges = []
    used = []
    for i in range(n_edges):
        # connect: anchor every edge i at a vertex of edges 0..i-1
        # (min_dfs_code requires a connected query graph)
        if used:
            s = used[draw(st.integers(0, len(used) - 1))]
        else:
            s = V(draw(st.integers(0, n_vars - 1)))
        d = V(draw(st.integers(0, n_vars - 1)))
        p = draw(st.integers(0, 3))
        edges.append((s, d, p))
        for v in (s, d):
            if v not in used:
                used.append(v)
    return QueryGraph.make(edges)


@settings(max_examples=60, deadline=None)
@given(small_graphs(), st.permutations(list(range(8))))
def test_canonical_code_invariant_under_relabeling(g, perm):
    """Property: min DFS code is invariant under variable renaming."""
    mapping = {V(i): V(perm[i]) for i in range(8)}
    g2 = QueryGraph.make([(mapping[e.src], mapping[e.dst], e.prop)
                          for e in g.edges])
    assert min_dfs_code(g) == min_dfs_code(g2)


@settings(max_examples=40, deadline=None)
@given(small_graphs())
def test_self_subgraph(g):
    assert is_subgraph_of(g, g)


def test_subgraph_iso_embedding_is_consistent():
    pat = QueryGraph.make([(V(0), V(1), 1)])
    q = QueryGraph.make([(V(0), V(1), 1), (V(1), V(2), 2)])
    emb = find_embedding(pat, q)
    assert emb is not None
    assert emb[V(0)] == V(0) and emb[V(1)] == V(1)
    assert len(all_embeddings(pat, q)) == 1


def test_subgraph_iso_respects_labels_and_direction():
    pat = QueryGraph.make([(V(0), V(1), 7)])
    q = QueryGraph.make([(V(0), V(1), 1)])
    assert not is_subgraph_of(pat, q)
    pat2 = QueryGraph.make([(V(0), V(1), 1), (V(1), V(0), 1)])
    q2 = QueryGraph.make([(V(0), V(1), 1)])
    assert not is_subgraph_of(pat2, q2)


def test_embeddings_injective_on_edges():
    # pattern with two identical-label edges cannot map onto one edge
    pat = QueryGraph.make([(V(0), V(1), 1), (V(0), V(2), 1)])
    q = QueryGraph.make([(V(0), V(1), 1)])
    assert not is_subgraph_of(pat, q)
