"""SPMD trace <-> ledger reconciliation: the per-step records a traced
SPMD query attaches to its root span must account for the engine's
communication ledger *exactly* -- same decisions, same byte formulas --
at any device count (CI runs this at 1, 2, and 4 devices).

Two invariants per query:

* the sum of traced step ``bytes`` equals the query's ``comm_bytes``
  delta (and, aggregated, the cumulative ``stats().comm_bytes``);
* the per-decision record counts equal the ``gather_steps`` /
  ``edge_shipped_steps`` / ``skipped_gathers`` / ``edge_cache_hits``
  counter deltas.

On a 1-device mesh nothing ships, so both sides are zero and the root
span carries the ``devices=1`` annotation instead of step records.
"""
import numpy as np
import pytest

from repro.core import (PartitionConfig, Session, build_plan,
                        generate_watdiv, generate_workload,
                        make_shape_queries)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

DECISION_COUNTERS = {"gather": "gather_steps",
                     "edge_ship": "edge_shipped_steps",
                     "skip": "skipped_gathers",
                     "edge_cached": "edge_cache_hits"}


@pytest.fixture(scope="module")
def spmd_setup():
    g = generate_watdiv(8_000, seed=5)
    wl = generate_workload(g, 500, seed=6)
    plan = build_plan(g, wl, PartitionConfig(kind="vertical", num_sites=4))
    return g, plan


def _shape_queries(g, per_shape=3, seed=9):
    rng = np.random.default_rng(seed)
    p = np.asarray(g.p)

    def rp():
        return int(p[rng.integers(0, len(p))])

    out = []
    for _ in range(per_shape):
        out.extend(make_shape_queries(rp).values())
    return out


def _counters(sess):
    extra = sess.stats().extra
    return {k: extra[k] for k in DECISION_COUNTERS.values()}


@pytest.mark.slow
def test_spmd_trace_reconciles_with_ledger(spmd_setup):
    g, plan = spmd_setup
    tracer = Tracer(enabled=True, capacity=256)
    sess = Session(plan, backend="spmd", tracer=tracer,
                   metrics_registry=MetricsRegistry())
    m = sess.engine.store.num_sites
    total_traced = 0
    for q in _shape_queries(g):
        before_comm = sess.stats().comm_bytes
        before = _counters(sess)
        sess.execute(q)
        delta_comm = sess.stats().comm_bytes - before_comm
        after = _counters(sess)
        root = tracer.store.spans()[-1]
        assert root.name == "query" and root.attrs["backend"] == "spmd"
        assert root.attrs["devices"] == m

        recs = [r for r in root.records if r["kind"] == "comm_step"]
        # invariant 1: traced step bytes sum to the ledger exactly
        assert sum(r["bytes"] for r in recs) == delta_comm
        total_traced += delta_comm

        # invariant 2: per-decision record counts == counter deltas
        for decision, counter in DECISION_COUNTERS.items():
            n_rec = sum(1 for r in recs if r["decision"] == decision)
            assert n_rec == after[counter] - before[counter], \
                f"{decision} records disagree with {counter}"

        if m > 1:
            # exactly one final gather per attempted capacity tier
            finals = [r for r in recs if r["decision"] == "final_gather"]
            assert len(finals) == len(root.attrs["capacity_tiers"])
            assert root.attrs["capacity_retries"] == \
                len(root.attrs["capacity_tiers"]) - 1
            for r in recs:
                assert r["bytes"] >= 0
                assert 0.0 <= r["occupancy"] <= 1.0
        else:
            assert recs == [] and delta_comm == 0

    # aggregate: the whole traced stream reconciles with the ledger
    assert total_traced == sess.stats().comm_bytes


@pytest.mark.slow
def test_routed_trace_carries_route_width_and_reconciles(spmd_setup):
    """Replica routing keeps the trace honest: every ``comm_step``
    record of a routed query carries ``route_width`` in [1, m] (the
    peer factor its byte formula used), the root span annotates the
    width and the routed flag, and the routed trace still reconciles
    with the ledger byte-for-byte -- delta zero on every query."""
    g, plan = spmd_setup
    tracer = Tracer(enabled=True, capacity=256)
    sess = Session(plan, backend="spmd", tracer=tracer,
                   metrics_registry=MetricsRegistry())
    m = sess.engine.store.num_sites
    assert sess.stats().extra["routing"] == float(m > 1)
    saw_narrow = False
    for q in _shape_queries(g):
        before = sess.stats().comm_bytes
        sess.execute(q)
        delta = sess.stats().comm_bytes - before
        root = tracer.store.spans()[-1]
        assert "route_width" in root.attrs
        w = root.attrs["route_width"]
        assert 1 <= w <= m
        assert root.attrs["routed"] == (w < m and m > 1)
        saw_narrow |= bool(root.attrs["routed"])
        recs = [r for r in root.records if r["kind"] == "comm_step"]
        # every record carries the width its byte formula used, and the
        # routed trace<->ledger delta is exactly zero
        assert all(r["route_width"] == w for r in recs)
        assert sum(r["bytes"] for r in recs) - delta == 0
    if m > 1:
        # the vertical allocation concentrates properties, so at least
        # one shape of the sweep must have routed below the full mesh
        assert saw_narrow
        assert sess.stats().extra["routed_queries"] > 0


@pytest.mark.slow
def test_spmd_trace_covers_retry_tiers(spmd_setup):
    """A query forced through the overflow retry ladder traces every
    attempted tier, and the bytes of *all* tiers are ledgered."""
    g, plan = spmd_setup
    tracer = Tracer(enabled=True, capacity=64)
    sess = Session(plan, backend="spmd", tracer=tracer,
                   metrics_registry=MetricsRegistry(),
                   spmd_capacity=8, spmd_max_capacity=1 << 20)
    q = _shape_queries(g, per_shape=1)[0]
    sess.execute(q)
    root = tracer.store.spans()[-1]
    tiers = root.attrs["capacity_tiers"]
    assert tiers == sorted(tiers)
    recs = [r for r in root.records if r["kind"] == "comm_step"]
    assert sum(r["bytes"] for r in recs) == sess.stats().comm_bytes
    if sess.engine.store.num_sites > 1 and len(tiers) > 1:
        # each attempt contributes a full set of step records
        attempts = {r["attempt"] for r in recs}
        assert attempts == set(range(len(tiers)))
        assert {r["capacity"] for r in recs} == set(tiers)


def test_spmd_disabled_tracer_records_nothing(spmd_setup):
    g, plan = spmd_setup
    tracer = Tracer(enabled=False)
    sess = Session(plan, backend="spmd", tracer=tracer,
                   metrics_registry=MetricsRegistry())
    sess.execute(_shape_queries(g, per_shape=1)[0])
    assert len(tracer.store) == 0
    # the ledger is tracing-independent
    assert sess.stats().queries == 1


@pytest.mark.slow
def test_spmd_ledger_identical_traced_vs_untraced(spmd_setup):
    """Enabling tracing must not change results or the ledger (tracing
    is host-side only; nothing new is traced inside shard_map)."""
    g, plan = spmd_setup
    qs = _shape_queries(g, per_shape=2)
    plain = Session(plan, backend="spmd",
                    metrics_registry=MetricsRegistry())
    traced = Session(plan, backend="spmd", trace=True,
                     metrics_registry=MetricsRegistry())
    rows_plain = [plain.execute(q).num_rows for q in qs]
    rows_traced = [traced.execute(q).num_rows for q in qs]
    assert rows_plain == rows_traced
    sp, st = plain.stats(), traced.stats()
    assert sp.comm_bytes == st.comm_bytes
    assert sp.extra["gather_steps"] == st.extra["gather_steps"]
    assert sp.extra["skipped_gathers"] == st.extra["skipped_gathers"]
