"""Fragmentation (Def. 3/10/12) and allocation (Def. 4/13, Alg. 2)
invariants, including property tests (hypothesis when available,
seeded-random equivalents otherwise)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests degrade to seeded random
    from seeded_fallback import given, settings, st

from repro.core import (Allocation, affinity_matrix, allocate,
                        allocate_experts, allocate_fragments,
                        generate_watdiv, generate_workload)
from repro.core.fragmentation import (MintermPredicate, SimplePredicate,
                                      enumerate_minterms)
from repro.core.matching import match_pattern


def test_fragmentation_covers_every_edge(partitioner_v, watdiv_small):
    """Def. 3: union of fragments (hot+cold) covers E(G)."""
    assert partitioner_v.frag.coverage_ok(watdiv_small)


def test_horizontal_covers_every_edge(partitioner_h, watdiv_small):
    assert partitioner_h.frag.coverage_ok(watdiv_small)


def test_redundancy_at_least_one(partitioner_v, partitioner_h, watdiv_small):
    assert partitioner_v.frag.redundancy_ratio(watdiv_small) >= 1.0
    assert partitioner_h.frag.redundancy_ratio(watdiv_small) >= 1.0


def test_vertical_fragment_edges_match_pattern_props(partitioner_v,
                                                     watdiv_small):
    g = watdiv_small
    for f in partitioner_v.frag.fragments:
        pat = partitioner_v.frag.patterns[f.pattern_idx]
        props = {p for p in pat.properties() if p >= 0}
        assert set(np.unique(g.p[f.edge_ids])) <= props


def test_minterms_partition_matches(partitioner_h, watdiv_small):
    """§5.2: the minterm predicates of one pattern partition its match
    set (each match satisfies exactly one minterm)."""
    frag = partitioner_h.frag
    by_pattern = {}
    for f in frag.fragments:
        by_pattern.setdefault(f.pattern_idx, []).append(f)
    checked = 0
    for pidx, frags in by_pattern.items():
        if len(frags) < 2:
            continue
        res = match_pattern(watdiv_small, frag.patterns[pidx])
        if res.num_rows == 0:
            continue
        masks = np.stack([f.minterm.mask(res) for f in frags])
        counts = masks.sum(axis=0)
        assert (counts <= 1).all()
        checked += 1
    assert checked >= 1


def test_enumerate_minterms_complete():
    sps = [SimplePredicate(-1, 5, True), SimplePredicate(-2, 9, True)]
    mts = enumerate_minterms(0, sps)
    assert len(mts) == 4
    signs = {tuple(t.equal for t in m.terms) for m in mts}
    assert signs == {(True, True), (True, False), (False, True),
                     (False, False)}


# ----------------------------------------------------------------------
# Allocation
# ----------------------------------------------------------------------

def test_allocation_is_partition(partitioner_v):
    alloc = partitioner_v.alloc
    assert alloc.is_partition(len(partitioner_v.frag.fragments))
    groups = alloc.groups()
    all_members = [fi for g in groups for fi in g]
    assert sorted(all_members) == list(range(len(partitioner_v.frag.fragments)))


def test_affinity_symmetric_nonnegative(partitioner_v, workload_small):
    from repro.core.mining import usage_matrix
    uniq, w = workload_small.dedup_normalized()
    U = usage_matrix(partitioner_v.selected_patterns, uniq)
    A = affinity_matrix(U, w)
    assert np.allclose(A, A.T)
    assert (A >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 12), st.integers(2, 4), st.integers(0, 1000))
def test_allocate_produces_m_nonempty_clusters(n, m, seed):
    if m > n:
        m = n
    rng = np.random.default_rng(seed)
    A = rng.random((n, n))
    A = A + A.T
    np.fill_diagonal(A, 0)
    alloc = allocate(A, m)
    assert alloc.is_partition(n)
    sites = set(alloc.site_of.tolist())
    assert len(sites) == m


def test_affinity_pairs_colocated():
    """Two fragments always accessed together must land on one site."""
    A = np.zeros((4, 4))
    A[0, 1] = A[1, 0] = 100.0
    A[2, 3] = A[3, 2] = 90.0
    alloc = allocate(A, 2)
    assert alloc.site_of[0] == alloc.site_of[1]
    assert alloc.site_of[2] == alloc.site_of[3]
    assert alloc.site_of[0] != alloc.site_of[2]


def test_expert_allocation_balanced():
    rng = np.random.default_rng(0)
    co = rng.random((16, 16))
    co = co + co.T
    out = allocate_experts(co, 4)
    counts = np.bincount(out, minlength=4)
    assert (counts == 4).all()


def test_expert_allocation_prefers_coactivated():
    co = np.zeros((8, 8))
    # two clear co-activation cliques
    for grp in ([0, 1, 2, 3], [4, 5, 6, 7]):
        for a in grp:
            for b in grp:
                if a != b:
                    co[a, b] = 10.0
    out = allocate_experts(co, 2)
    assert len({out[i] for i in [0, 1, 2, 3]}) == 1
    assert len({out[i] for i in [4, 5, 6, 7]}) == 1
