"""Telemetry layer unit tests: span tracer, metrics registry,
exporters, and the engine-side wiring (root spans, metric publication,
hook-error isolation)."""
import json

import pytest

from repro.core import PartitionConfig, Session, build_plan
from repro.core import generate_watdiv, generate_workload
from repro.obs.export import (REQUIRED_METRICS, SNAPSHOT_SCHEMA, dump_spans,
                              registry_from_snapshot, snapshot, to_prom_text,
                              validate_snapshot)
from repro.obs.metrics import (Gauge, Histogram, MetricsRegistry,
                               get_registry, set_registry)
from repro.obs.trace import (NULL_SPAN, TraceStore, Tracer, enable_tracing,
                             get_tracer, set_tracer)


class FakeClock:
    """Deterministic monotonic clock: each call advances by ``step``."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# ----------------------------------------------------------------------
# Tracer / spans
# ----------------------------------------------------------------------

def test_span_nesting_and_ordering():
    tr = Tracer(enabled=True, clock=FakeClock())
    with tr.span("query", backend="x") as root:
        with tr.span("site_match", subquery=0) as a:
            a.set("rows", 3)
        with tr.span("join", subquery=1) as b:
            with tr.span("inner") as c:
                assert tr.current is c
    assert tr.current is None
    roots = tr.store.spans()
    assert len(roots) == 1 and roots[0] is root
    assert [s.name for s in root.walk()] == ["query", "site_match", "join",
                                             "inner"]
    assert a.parent_id == root.span_id
    assert b.parent_id == root.span_id
    assert c.parent_id == b.span_id
    assert {s.trace_id for s in root.walk()} == {root.trace_id}
    # fake clock: start/end strictly ordered, duration deterministic
    assert root.start < a.start < a.end <= b.start < c.start
    assert root.end > c.end
    assert root.duration > 0
    assert root.attrs == {"backend": "x"} and a.attrs["rows"] == 3


def test_two_roots_get_distinct_traces():
    tr = Tracer(enabled=True, clock=FakeClock())
    with tr.span("query"):
        pass
    with tr.span("query"):
        pass
    r1, r2 = tr.store.spans()
    assert r1.trace_id != r2.trace_id
    assert tr.store.finished_total == 2


def test_ring_buffer_caps_memory():
    tr = Tracer(enabled=True, clock=FakeClock(), capacity=4)
    for i in range(10):
        with tr.span("query", i=i):
            pass
    assert len(tr.store) == 4
    assert tr.store.finished_total == 10
    assert [s.attrs["i"] for s in tr.store.spans()] == [6, 7, 8, 9]


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    sp = tr.span("query", backend="x")
    assert sp is NULL_SPAN                  # shared instance, no alloc
    with sp as inner:
        inner.set("rows", 1)                # all no-ops
        tr.annotate(rows=2)
        tr.add_record({"bytes": 3})
    assert len(tr.store) == 0 and tr.store.finished_total == 0
    assert NULL_SPAN.attrs == {} and NULL_SPAN.records == []


def test_exception_unwinds_span_stack():
    tr = Tracer(enabled=True, clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("query"):
            with tr.span("join"):
                raise RuntimeError("boom")
    assert tr.current is None
    (root,) = tr.store.spans()
    assert root.end is not None
    assert all(s.end is not None for s in root.walk())
    # tracer still usable afterwards
    with tr.span("query"):
        pass
    assert tr.store.finished_total == 2


def test_add_record_lands_on_innermost_span():
    tr = Tracer(enabled=True, clock=FakeClock())
    with tr.span("query") as root:
        tr.add_record({"a": 1})
        with tr.span("child") as ch:
            tr.add_record({"b": 2})
    assert root.records == [{"a": 1}]
    assert ch.records == [{"b": 2}]


def test_store_jsonl_roundtrip(tmp_path):
    tr = Tracer(enabled=True, clock=FakeClock())
    with tr.span("query", backend="spmd"):
        tr.add_record({"bytes": 96})
        with tr.span("child"):
            pass
    path = tmp_path / "spans.jsonl"
    assert dump_spans(tr, str(path)) == 2
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[0]["name"] == "query" and lines[0]["parent_id"] is None
    assert lines[0]["records"] == [{"bytes": 96}]
    assert lines[1]["parent_id"] == lines[0]["span_id"]


def test_default_tracer_swap_restores():
    prev = get_tracer()
    try:
        t = enable_tracing(capacity=8)
        assert get_tracer() is t and t.enabled
    finally:
        set_tracer(prev)
    assert get_tracer() is prev


def test_trace_store_rejects_bad_capacity():
    with pytest.raises(ValueError):
        TraceStore(0)


# ----------------------------------------------------------------------
# Histogram percentile math
# ----------------------------------------------------------------------

def test_histogram_bucket_edges_le_semantics():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 99.0):
        h.observe(v)
    # le semantics: a value equal to a bound lands in that bound's bucket
    assert h.counts == [2, 2, 2, 1]
    assert h.count == 7 and h.sum == pytest.approx(111.0)


def test_histogram_percentiles():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    assert h.percentile(0.5) == 0.0          # empty -> 0.0
    for _ in range(10):
        h.observe(1.5)                       # all in (1, 2]
    # all mass in one bucket: interpolation stays within (1, 2]
    assert 1.0 <= h.percentile(0.01) <= 2.0
    assert 1.0 <= h.percentile(0.99) <= 2.0
    assert h.percentile(1.0) == 2.0          # upper edge of the bucket
    h.observe(100.0)                         # +Inf bucket
    # rank in the overflow bucket reports the largest finite bound
    assert h.percentile(1.0) == 4.0
    with pytest.raises(ValueError):
        h.percentile(1.5)


def test_histogram_merge_and_rebucket_refusal():
    a = Histogram(buckets=(1.0, 2.0))
    b = Histogram(buckets=(1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    b.observe(9.0)
    a.merge(b)
    assert a.counts == [1, 1, 1] and a.count == 3
    with pytest.raises(ValueError):
        a.merge(Histogram(buckets=(1.0, 3.0)))
    with pytest.raises(ValueError):
        Histogram(buckets=())
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

def test_registry_families_and_type_safety():
    reg = MetricsRegistry()
    c1 = reg.counter("repro_x_total", backend="a")
    c2 = reg.counter("repro_x_total", backend="b")
    assert c1 is not c2
    assert reg.counter("repro_x_total", backend="a") is c1
    with pytest.raises(TypeError):
        reg.gauge("repro_x_total", backend="a")
    reg.histogram("repro_h", buckets=(1.0,))
    with pytest.raises(ValueError):
        reg.histogram("repro_h", buckets=(2.0,))


def test_gauge_history_dedups_unchanged_sets():
    g = Gauge()
    g.set(1.0)
    g.set(1.0)
    g.set(2.0)
    g.set(2.0)
    assert g.value == 2.0
    assert [v for _, v in g.history] == [1.0, 2.0]


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c", backend="x").inc(2)
    b.counter("c", backend="x").inc(3)
    b.counter("c", backend="y").inc(7)
    b.gauge("g").set(5.0)
    b.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    a.merge(b)
    assert a.counter("c", backend="x").value == 5
    assert a.counter("c", backend="y").value == 7
    assert a.gauge("g").value == 5.0
    assert a.histogram("h", buckets=(1.0, 2.0)).count == 1


# ----------------------------------------------------------------------
# Exporters
# ----------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("repro_queries_total", backend="local").inc(4)
    g = reg.gauge("repro_epochs", backend="adaptive")
    g.set(1.0)
    g.set(2.0)
    h = reg.histogram("repro_query_latency_seconds", backend="local")
    for v in (1e-4, 1e-3, 0.5, 20.0):
        h.observe(v)
    return reg


def test_snapshot_roundtrip_exact():
    reg = _populated_registry()
    doc = snapshot(registry=reg)
    assert doc["schema"] == SNAPSHOT_SCHEMA
    rebuilt = registry_from_snapshot(doc)
    assert snapshot(registry=rebuilt) == doc
    with pytest.raises(ValueError):
        registry_from_snapshot({"schema": "nope"})


def test_validate_snapshot():
    reg = _populated_registry()
    doc = snapshot(registry=reg)
    validate_snapshot(doc, required=("repro_queries_total",
                                     "repro_query_latency_seconds"))
    with pytest.raises(ValueError, match="missing"):
        validate_snapshot(doc, required=("repro_not_there_total",))
    with pytest.raises(ValueError, match="schema"):
        validate_snapshot({"schema": "other"}, required=())
    bad = snapshot(registry=reg)
    bad["histograms"][0]["counts"][0] += 1
    with pytest.raises(ValueError, match="sum"):
        validate_snapshot(bad, required=())


def test_prom_text_exposition():
    reg = _populated_registry()
    text = to_prom_text(reg)
    assert "# TYPE repro_queries_total counter" in text
    assert 'repro_queries_total{backend="local"} 4' in text
    assert "# TYPE repro_query_latency_seconds histogram" in text
    assert 'le="+Inf"' in text
    assert 'repro_query_latency_seconds_count{backend="local"} 4' in text
    # cumulative bucket series are monotone non-decreasing
    cum = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
           if ln.startswith("repro_query_latency_seconds_bucket")]
    assert cum == sorted(cum) and cum[-1] == 4


# ----------------------------------------------------------------------
# Engine wiring (root spans, metric publication, hook isolation)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_plan():
    g = generate_watdiv(2_000, seed=3)
    wl = generate_workload(g, 120, seed=4)
    return g, wl, build_plan(g, wl, PartitionConfig(kind="vertical",
                                                    num_sites=4))


def test_session_trace_and_metrics_knobs(tiny_plan):
    g, wl, plan = tiny_plan
    reg = MetricsRegistry()
    sess = Session(plan, backend="local", trace=True, metrics_registry=reg)
    assert sess.tracer.enabled and sess.metrics is reg
    qs = wl.queries[:5]
    for q in qs:
        sess.execute(q)
    roots = sess.tracer.store.spans()
    assert len(roots) == len(qs)
    for root in roots:
        assert root.name == "query"
        assert root.attrs["backend"] == "local"
        # _finish annotated the root with the per-query ledger
        assert {"rows", "comm_bytes", "response_time"} <= set(root.attrs)
    # multi-subquery queries show site_match/join children
    assert any(root.find("site_match") for root in roots)
    # metric publication matches the engine counters
    st = sess.stats()
    assert reg.counter("repro_queries_total",
                       backend="local").value == len(qs)
    assert reg.counter("repro_comm_bytes_total",
                       backend="local").value == st.comm_bytes
    h = reg.histogram("repro_query_latency_seconds", backend="local")
    assert h.count == len(qs)
    assert h.sum == pytest.approx(st.response_time)
    # default engines stay untraced
    assert not Session(plan, backend="local").tracer.enabled


def test_hook_error_does_not_abort_query(tiny_plan):
    g, wl, plan = tiny_plan
    reg = MetricsRegistry()
    sess = Session(plan, backend="local", metrics_registry=reg)
    seen = []

    def bad_hook(q, r):
        raise ValueError("observer bug")

    sess.post_execute_hooks.append(bad_hook)
    sess.post_execute_hooks.append(lambda q, r: seen.append(r.num_rows))
    q = wl.queries[0]
    with pytest.warns(RuntimeWarning, match="post_execute_hook"):
        r1 = sess.execute(q)
    assert r1 is not None
    assert len(seen) == 1                      # later hooks still ran
    # warns once per engine; keeps counting
    import warnings as _w
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        sess.execute(q)
    assert not [w for w in rec if "post_execute_hook" in str(w.message)]
    assert len(seen) == 2
    assert sess.stats().extra["hook_errors"] == 2.0
    assert reg.counter("repro_hook_errors_total",
                       backend="local").value == 2.0


def test_default_registry_swap_restores():
    prev = get_registry()
    try:
        reg = MetricsRegistry()
        assert set_registry(reg) is prev
        assert get_registry() is reg
    finally:
        set_registry(prev)
    assert get_registry() is prev


def test_adaptive_epoch_gauges(tiny_plan):
    from repro.online.loop import AdaptiveConfig

    g, wl, plan = tiny_plan
    reg = MetricsRegistry()
    sess = Session(plan, backend="adaptive", metrics_registry=reg,
                   adaptive_config=AdaptiveConfig(epoch_len=5))
    for q in wl.queries[:10]:
        sess.execute(q)
    eng = sess.engine
    assert eng.epoch == 2
    # "index" carries the id of the last *closed* epoch (0-based)
    assert reg.gauge("repro_epoch_index", backend="adaptive").value == 1.0
    assert reg.gauge("repro_epoch_queries", backend="adaptive").value == 5.0
    # drift report gauges published whenever the detector ran
    names = reg.names()
    assert "repro_epoch_tv_distance" in names
    assert "repro_epoch_coverage_loss" in names
    assert "repro_epoch_moved_bytes" in names
    assert "repro_epoch_replica_ships" in names
    # inner host engine shares the session registry
    assert reg.counter("repro_queries_total", backend="local").value == 10


def test_adaptive_trace_nesting(tiny_plan):
    g, wl, plan = tiny_plan
    sess = Session(plan, backend="adaptive", trace=True,
                   metrics_registry=MetricsRegistry())
    sess.execute(wl.queries[0])
    (root,) = sess.tracer.store.spans()
    assert root.attrs["backend"] == "adaptive"
    inner = root.find("query")
    assert len(inner) == 2                     # adaptive root + local child
    assert inner[1].attrs["backend"] == "local"


def test_required_metrics_pre_registered(tiny_plan):
    """Every REQUIRED_METRICS name exists before any query runs, so the
    CI snapshot gate cannot pass vacuously."""
    g, wl, plan = tiny_plan
    reg = MetricsRegistry()
    sess = Session(plan, backend="spmd", metrics_registry=reg)
    sess.execute(wl.queries[0])                # registers _finish metrics
    doc = snapshot(registry=reg)
    validate_snapshot(doc, required=REQUIRED_METRICS)


# ----------------------------------------------------------------------
# Thread safety: the serving front door hammers these series from a
# dispatcher thread while submit threads shed/count and exporters
# scrape, so lost updates here silently corrupt the capacity model.
# ----------------------------------------------------------------------

def test_metrics_concurrent_hammer():
    """N threads x M updates on the SAME counter/gauge/histogram plus
    racing first-registration through the registry: final counts must
    be exact (the unlocked `+=` / check-then-insert versions lose
    updates and duplicate instances under this load)."""
    import threading

    reg = MetricsRegistry()
    n_threads, n_iter = 8, 2_000
    barrier = threading.Barrier(n_threads)
    errors = []

    def hammer(tid: int) -> None:
        try:
            barrier.wait()
            for i in range(n_iter):
                # racing fetch-or-create of shared series every round:
                # a lost race would hand this thread a private instance
                # whose increments vanish from the registry
                reg.counter("hammer_total", backend="serve").inc()
                reg.histogram("hammer_seconds",
                              backend="serve").observe(i * 1e-4)
                reg.gauge("hammer_depth", backend="serve").set(float(i))
                reg.counter(f"private_{tid}_total").inc()
        except Exception as exc:  # pragma: no cover - failure reporting
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = n_threads * n_iter
    assert reg.counter("hammer_total", backend="serve").value == total
    h = reg.histogram("hammer_seconds", backend="serve")
    assert h.count == total
    assert sum(h.counts) == total                # no torn bucket writes
    for t in range(n_threads):
        assert reg.counter(f"private_{t}_total").value == n_iter
    g = reg.gauge("hammer_depth", backend="serve")
    assert 0.0 <= g.value <= float(n_iter - 1)


def test_metrics_concurrent_collect_while_writing():
    """Exporters scrape (collect + percentile) concurrently with
    writers; the walk must never blow up on a mid-registration dict and
    percentiles must read a consistent (counts, count) pair."""
    import threading

    reg = MetricsRegistry()
    stop = threading.Event()
    errors = []

    def writer(tid: int) -> None:
        try:
            i = 0
            while not stop.is_set():
                reg.counter(f"w{tid}_{i % 50}_total").inc()
                reg.histogram("lat_seconds").observe((i % 100) * 1e-4)
                i += 1
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    def scraper() -> None:
        try:
            while not stop.is_set():
                for _name, _labels, m in reg.collect():
                    if isinstance(m, Histogram):
                        assert m.percentile(0.99) >= 0.0
                snapshot(registry=reg)
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(4)] + [threading.Thread(target=scraper)]
    for t in threads:
        t.start()
    import time as _time
    _time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
